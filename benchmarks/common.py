"""Shared benchmark plumbing: timing, CSV rows, cached DeViBench build."""
from __future__ import annotations

import functools
import os
import time
from typing import Callable, Dict, List

QUICK = os.environ.get("BENCH_QUICK", "1") != "0"


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


@functools.lru_cache()
def shared_benchmark(quick: bool = True):
    from repro.devibench import pipeline as dvb
    return dvb.generate(n_scenes_per_cat=1 if quick else 3,
                        questions_per_obj=2 if quick else 4,
                        seed=0, n_frames=20 if quick else 60)


@functools.lru_cache()
def shared_calibrator(quick: bool = True):
    from repro.devibench.pipeline import fit_confidence_calibrator
    return fit_confidence_calibrator(shared_benchmark(quick))

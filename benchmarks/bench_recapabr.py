"""Fig. 9 — ReCapABR latency vs bandwidth-fluctuation frequency.

WebRTC(GCC) vs GCC+ReCapABR at 1-4 industry-level switches per minute;
reports average latency, the CDF point P(latency < 200 ms), and the gain
growth with fluctuation frequency.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_calibrator, timed
from repro.api import grid, run_scenarios

DUR = 60.0


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    freqs = [1, 4] if quick else [1, 2, 3, 4]
    seeds = [0] if quick else [0, 1, 2]
    rows, gains = [], {}
    for f in freqs:
        specs = [s.with_(scene_seed=s.seed, trace_seed=s.seed)
                 for s in grid("webrtc", duration=DUR,
                               trace_kwargs=dict(switches_per_min=f),
                               system=["webrtc", "webrtc+recap"],
                               seed=seeds)]
        result, us_tot = timed(run_scenarios, specs, calibrator=cal)
        base_r = result.select(system="webrtc")
        recap_r = result.select(system="webrtc+recap")
        base = base_r.values("avg_latency_ms")
        recap = recap_r.values("avg_latency_ms")
        cdf_b = [m.frac_below(200.0) for m in base_r.metrics]
        cdf_r = [m.frac_below(200.0) for m in recap_r.metrics]
        gain = np.mean(base) - np.mean(recap)
        gains[f] = gain
        rows.append(Row(f"fig9a.latency_gain@{f}fluct_per_min", us_tot,
                        f"webrtc={np.mean(base):.0f}ms,"
                        f"recap={np.mean(recap):.0f}ms,gain={gain:.0f}ms"))
        rows.append(Row(f"fig9b.frac_below_200ms@{f}fluct", us_tot,
                        f"webrtc={np.mean(cdf_b):.2f},"
                        f"recap={np.mean(cdf_r):.2f}"))
    fs = sorted(gains)
    rows.append(Row("fig9.gain_grows_with_fluctuation", 0.0,
                    f"{gains[fs[0]]:.0f}ms@{fs[0]} -> "
                    f"{gains[fs[-1]]:.0f}ms@{fs[-1]}"))
    print(f"[fig9] latency gains by fluct freq: "
          f"{ {k: round(v) for k, v in gains.items()} } "
          "(paper: 23.7ms@1 -> 148.4ms@4)")
    return rows

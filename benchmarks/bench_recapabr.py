"""Fig. 9 — ReCapABR latency vs bandwidth-fluctuation frequency.

WebRTC(GCC) vs GCC+ReCapABR at 1-4 industry-level switches per minute;
reports average latency, the CDF point P(latency < 200 ms), and the gain
growth with fluctuation frequency.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_calibrator, timed
from repro.core.session import SessionConfig, run_session
from repro.net.traces import fluctuating_trace
from repro.video.scenes import make_scene

DUR = 60.0


def _avg_latency(use_recap: bool, freq: float, seed: int, cal) -> tuple:
    sc = make_scene("retail", False, seed=seed)
    tr = fluctuating_trace(DUR, switches_per_min=freq, seed=seed)
    m = run_session(sc, [], tr, SessionConfig(
        duration=DUR, use_recap=use_recap, use_zeco=False, cc_kind="gcc",
        seed=seed), calibrator=cal)
    return m.avg_latency_ms, m.frac_below(200.0)


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    freqs = [1, 4] if quick else [1, 2, 3, 4]
    seeds = [0] if quick else [0, 1, 2]
    rows, gains = [], {}
    for f in freqs:
        base, recap, cdf_b, cdf_r, us_tot = [], [], [], [], 0.0
        for s in seeds:
            (b, cb), us1 = timed(_avg_latency, False, f, s, cal)
            (r, cr), us2 = timed(_avg_latency, True, f, s, cal)
            base.append(b); recap.append(r)
            cdf_b.append(cb); cdf_r.append(cr)
            us_tot += us1 + us2
        gain = np.mean(base) - np.mean(recap)
        gains[f] = gain
        rows.append(Row(f"fig9a.latency_gain@{f}fluct_per_min", us_tot,
                        f"webrtc={np.mean(base):.0f}ms,"
                        f"recap={np.mean(recap):.0f}ms,gain={gain:.0f}ms"))
        rows.append(Row(f"fig9b.frac_below_200ms@{f}fluct", us_tot,
                        f"webrtc={np.mean(cdf_b):.2f},"
                        f"recap={np.mean(cdf_r):.2f}"))
    fs = sorted(gains)
    rows.append(Row("fig9.gain_grows_with_fluctuation", 0.0,
                    f"{gains[fs[0]]:.0f}ms@{fs[0]} -> "
                    f"{gains[fs[-1]]:.0f}ms@{fs[-1]}"))
    print(f"[fig9] latency gains by fluct freq: "
          f"{ {k: round(v) for k, v in gains.items()} } "
          "(paper: 23.7ms@1 -> 148.4ms@4)")
    return rows

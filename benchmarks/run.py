"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and a
human summary per figure.  BENCH_QUICK=0 runs the full-size versions.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import QUICK


def main() -> None:
    from benchmarks import (bench_confidence, bench_devibench, bench_e2e,
                            bench_fleet, bench_kernels, bench_measurement,
                            bench_overhead, bench_recapabr,
                            bench_saturation, bench_zecostream)
    modules = [
        ("fig2_measurement", bench_measurement),
        ("fig3_saturation", bench_saturation),
        ("fig9_recapabr", bench_recapabr),
        ("fig10_confidence", bench_confidence),
        ("fig11_zecostream", bench_zecostream),
        ("fig13_e2e", bench_e2e),
        ("fig14_15_overhead", bench_overhead),
        ("table2_devibench", bench_devibench),
        ("kernels", bench_kernels),
        ("fleet", bench_fleet),
    ]
    all_rows = []
    failures = []
    for name, mod in modules:
        print(f"\n=== {name} ===", flush=True)
        try:
            all_rows.extend(mod.run(QUICK))
        except Exception:
            failures.append(name)
            traceback.print_exc()

    print("\nname,us_per_call,derived")
    for r in all_rows:
        print(r.csv())
    if failures:
        print(f"\nFAILED benchmarks: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

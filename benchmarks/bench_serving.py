"""Serving-bridge benchmark: engine throughput + fleet-served latency.

Stages, all CPU-runnable on the seeded reduced-config model:

1. **Engine drain** — N plain requests through the continuous-batching
   engine (the `launch/serve.py` workload): wall-clock tokens/sec plus
   simulated TTFT percentiles and slot / KV-page utilization from
   `EngineStats`.
2. **Fleet(server="engine")** — a tiny engine-served scenario end to
   end: per-session TTFT/queueing percentiles out of `SessionMetrics`.
3. **Eviction** (`eviction.*`) — one long streaming session (≫ max_len
   tokens of frame context) run twice, sink+recent eviction vs legacy
   rollover: context-retention counters, accuracy, and TTFT for both
   overflow policies side by side.

Wall-clock absolutes move with the runner; the committed
BENCH_serving.json is gated on METRIC COVERAGE only (every committed
metric key must still be produced), mirroring the BENCH_kernels.json
policy — see `benchmarks.snapshot.check_serving_coverage`.

    PYTHONPATH=src python -m benchmarks.bench_serving          # print
    PYTHONPATH=src python -m benchmarks.bench_serving --write  # snapshot
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np


def bench_engine(requests: int = 8, max_new: int = 16,
                 max_batch: int = 4, prompt_len: int = 16) -> Dict:
    """Drain N random-prompt requests; wall tok/s + simulated latency."""
    from repro.configs import registry
    from repro.models import transformer as tfm
    from repro.models.config import reduced
    from repro.serving.engine import Engine, Request

    cfg = reduced(registry.get_config("qwen3-0.6b"),
                  dtype="float32", param_dtype="float32")
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=max_batch, max_len=256,
                 step_dt=0.01)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, now=0.0)
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    ttft = [r.ttft for r in done if r.ttft is not None]
    st = eng.stats
    return {
        "engine.tokens_per_sec": st.tokens_out / wall,
        "engine.ttft_p50_ms": 1e3 * float(np.percentile(ttft, 50)),
        "engine.ttft_p95_ms": 1e3 * float(np.percentile(ttft, 95)),
        "engine.slot_utilization": st.slot_utilization,
        "engine.kv_peak_utilization": st.kv_peak_utilization,
        "engine.requests": len(done),
        "engine.wall_s": wall,
    }


def bench_fleet_served(n_sessions: int = 3, duration: float = 3.0) -> Dict:
    """A tiny engine-served fleet scenario; per-session serving
    percentiles aggregated over the fleet."""
    from repro.core.scenario import ScenarioSpec, grid, run_scenarios

    base = ScenarioSpec(duration=duration, frame_h=64, frame_w=64,
                        scene="retail", qa="periodic",
                        qa_kwargs=dict(start=1.0, period=1.0, count=2,
                                       answer_window=1.0),
                        server="engine",
                        engine_kwargs=dict(max_len=128, step_dt=0.004))
    specs = [base.with_(seed=k, scene_seed=k, trace_seed=k,
                        tag=f"serve-{k}") for k in range(n_sessions)]
    t0 = time.perf_counter()
    result = run_scenarios(specs)
    wall = time.perf_counter() - t0
    ttfts = [t for m in result.metrics for t in m.server_ttfts]
    queues = [q for m in result.metrics for q in m.server_queue_delays]
    return {
        "fleet.sessions": len(result),
        "fleet.queries": len(ttfts),
        "fleet.ttft_p50_ms": 1e3 * float(np.percentile(ttfts, 50)),
        "fleet.ttft_p95_ms": 1e3 * float(np.percentile(ttfts, 95)),
        "fleet.queue_p50_ms": 1e3 * float(np.percentile(queues, 50)),
        "fleet.queue_p95_ms": 1e3 * float(np.percentile(queues, 95)),
        "fleet.wall_s": wall,
    }


def bench_eviction(duration: float = 8.0, max_len: int = 64) -> Dict:
    """One long streaming session (frame tokens ≫ max_len), engine-served
    under both overflow policies: sink+recent eviction (default) vs
    legacy close+reopen rollover.  At fps=10 / patch_grid=2 the session
    streams `40 * duration` tokens — 5x max_len at the defaults — so
    both policies trigger many times."""
    from repro.core.scenario import ScenarioSpec, run_scenarios

    base = ScenarioSpec(duration=duration, frame_h=64, frame_w=64,
                        scene="retail", qa="periodic",
                        qa_kwargs=dict(start=1.0, period=1.0,
                                       count=int(duration) - 1,
                                       answer_window=1.0),
                        server="engine")
    out: Dict[str, float] = {}
    t0 = time.perf_counter()
    for label, evict in (("", True), ("rollover_", False)):
        spec = base.with_(engine_kwargs=dict(
            max_len=max_len, step_dt=0.004, eviction=evict))
        m = run_scenarios([spec]).metrics[0]
        out[f"eviction.{label}evictions"] = float(m.server_evictions)
        out[f"eviction.{label}evicted_tokens"] = float(
            m.server_evicted_tokens)
        out[f"eviction.{label}rollovers"] = float(m.server_rollovers)
        out[f"eviction.{label}accuracy"] = float(m.accuracy)
        out[f"eviction.{label}ttft_p50_ms"] = float(m.ttft_p50_ms)
    out["eviction.streamed_tokens"] = 4.0 * base.fps * duration
    out["eviction.wall_s"] = time.perf_counter() - t0
    return out


def run(quick: bool = True) -> Dict[str, float]:
    """All serving metrics as one flat {name: value} dict (the snapshot
    `metrics` payload)."""
    from benchmarks.bench_load import bench_load

    metrics = dict(bench_engine(requests=8 if quick else 32,
                                max_new=8 if quick else 32))
    metrics.update(bench_fleet_served(n_sessions=2 if quick else 8))
    # eviction vs rollover keeps one shape too: the A/B needs both
    # policies to trigger, which `quick` sizing would not guarantee
    metrics.update(bench_eviction(duration=6.0 if quick else 12.0))
    # the open-loop capacity-knee sweep keeps one shape regardless of
    # `quick` so the coverage gate sees a stable load.* key set
    metrics.update(bench_load())
    return metrics


def _main() -> None:
    import argparse

    from benchmarks.snapshot import (BENCH_SCHEMA, SERVING_SNAPSHOT_PATH,
                                     env_knobs, machine_info,
                                     save_serving_snapshot)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help=f"write {SERVING_SNAPSHOT_PATH}")
    ap.add_argument("--full", action="store_true",
                    help="larger request counts / fleet")
    args = ap.parse_args()
    metrics = run(quick=not args.full)
    for k in sorted(metrics):
        print(f"  {k:32s} {metrics[k]:.3f}")
    if args.write:
        doc = {"schema": BENCH_SCHEMA, "kind": "serving",
               "machine": machine_info(), "env": env_knobs(),
               "metrics": metrics}
        save_serving_snapshot(doc)
        print(f"wrote {SERVING_SNAPSHOT_PATH}")


if __name__ == "__main__":
    _main()

"""Committed benchmark snapshots: schema, validation, regression gate.

`bench_fleet --rollout` writes BENCH_fleet.json at the repo root — a
schema-versioned (`artic.bench.snapshot/v1`) record of the eager vs
rollout throughput sweep plus the roofline attribution, with enough
machine/env context to judge whether two snapshots are comparable at
all.  CI re-runs the sweep and fails the build if the fresh numbers
regress more than REGRESSION_TOL against the committed snapshot
(`python -m benchmarks.snapshot --check`), so perf changes land as a
reviewed diff of this file, never silently.

Ratios, not absolutes, are what the gate compares: sessions/sec moves
with the runner's hardware, but rollout-vs-eager measured in the SAME
process is stable across machines.
"""
from __future__ import annotations

import json
import os
import platform
import sys
from typing import Dict, List, Optional

BENCH_SCHEMA = "artic.bench.snapshot/v1"
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SNAPSHOT_PATH = os.path.join(_ROOT, "BENCH_fleet.json")
KERNELS_SNAPSHOT_PATH = os.path.join(_ROOT, "BENCH_kernels.json")
SERVING_SNAPSHOT_PATH = os.path.join(_ROOT, "BENCH_serving.json")
REGRESSION_TOL = 0.10

# sessions/sec of the eager (per-tick) fleet on the SAME workload the
# rollout sweep runs (the fleet-thumb preset: 64x64 frames, probe
# stride 2), measured on the reference runner at the PR-6 branch point.
# The rollout PR does not touch the eager tick path, so these equal the
# PR-5 tip on this workload.  They are the denominator of
# `summary.vs_pinned_eager`; comparing against a baseline from a
# different workload (e.g. the 256x256 hetero grid) would silently
# inflate the headline number several-fold.
PINNED_EAGER_BASELINE = {"8": 55.29, "64": 82.27, "256": 93.33}

_ENV_KNOBS = ("XLA_FLAGS", "JAX_PLATFORMS", "BENCH_QUICK",
              "OMP_NUM_THREADS", "JAX_ENABLE_X64")


def machine_info() -> Dict:
    import jax
    return {
        "platform": platform.platform(),
        "processor": platform.processor() or platform.machine(),
        "cpu_count": os.cpu_count(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
    }


def env_knobs() -> Dict[str, Optional[str]]:
    return {k: os.environ.get(k) for k in _ENV_KNOBS}


def validate_snapshot(doc: Dict) -> None:
    """Structural validation of a BENCH_fleet.json document; raises
    ValueError with the offending path on the first mismatch."""
    def need(cond, path):
        if not cond:
            raise ValueError(f"invalid bench snapshot: {path}")

    need(isinstance(doc, dict), "document must be an object")
    need(doc.get("schema") == BENCH_SCHEMA,
         f"schema must be {BENCH_SCHEMA!r} (got {doc.get('schema')!r})")
    need(isinstance(doc.get("machine"), dict), "machine")
    for k in ("platform", "python", "jax", "devices"):
        need(k in doc["machine"], f"machine.{k}")
    need(isinstance(doc.get("env"), dict), "env")
    need(isinstance(doc.get("baseline"), dict), "baseline")
    need(isinstance(doc["baseline"].get("sessions_per_sec"), dict),
         "baseline.sessions_per_sec")
    cells = doc.get("cells")
    need(isinstance(cells, list) and cells, "cells must be non-empty")
    for i, c in enumerate(cells):
        need(isinstance(c, dict), f"cells[{i}]")
        for k in ("n", "window", "eager_sessions_per_sec",
                  "rollout_sessions_per_sec", "median_ratio"):
            need(k in c, f"cells[{i}].{k}")
        need(int(c["n"]) > 0, f"cells[{i}].n > 0")
        need(float(c["rollout_sessions_per_sec"]) > 0,
             f"cells[{i}].rollout_sessions_per_sec > 0")
        need(float(c["median_ratio"]) > 0, f"cells[{i}].median_ratio > 0")
        if "roofline" in c:
            for k in ("flops", "bytes_accessed", "step_time_lb_s",
                      "bottleneck"):
                need(k in c["roofline"], f"cells[{i}].roofline.{k}")
    need(isinstance(doc.get("summary"), dict), "summary")


def validate_kernels_snapshot(doc: Dict) -> None:
    """Structural validation of a BENCH_kernels.json document — the same
    `artic.bench.snapshot/v1` envelope (schema/machine/env) with a
    `rows` list of kernel-microbench CSV rows instead of sweep cells."""
    def need(cond, path):
        if not cond:
            raise ValueError(f"invalid kernels snapshot: {path}")

    need(isinstance(doc, dict), "document must be an object")
    need(doc.get("schema") == BENCH_SCHEMA,
         f"schema must be {BENCH_SCHEMA!r} (got {doc.get('schema')!r})")
    need(doc.get("kind") == "kernels", "kind must be 'kernels'")
    need(isinstance(doc.get("machine"), dict), "machine")
    for k in ("platform", "python", "jax", "devices"):
        need(k in doc["machine"], f"machine.{k}")
    need(isinstance(doc.get("env"), dict), "env")
    rows = doc.get("rows")
    need(isinstance(rows, list) and rows, "rows must be non-empty")
    for i, r in enumerate(rows):
        need(isinstance(r, dict), f"rows[{i}]")
        need(isinstance(r.get("name"), str) and r["name"], f"rows[{i}].name")
        need(float(r.get("us_per_call", -1.0)) >= 0.0,
             f"rows[{i}].us_per_call >= 0")
        need(isinstance(r.get("derived"), str), f"rows[{i}].derived")


def validate_serving_snapshot(doc: Dict) -> None:
    """Structural validation of a BENCH_serving.json document — the same
    `artic.bench.snapshot/v1` envelope with a flat `metrics` dict
    (tokens/sec, TTFT percentiles, slot/KV utilization) from
    `benchmarks.bench_serving.run`."""
    def need(cond, path):
        if not cond:
            raise ValueError(f"invalid serving snapshot: {path}")

    need(isinstance(doc, dict), "document must be an object")
    need(doc.get("schema") == BENCH_SCHEMA,
         f"schema must be {BENCH_SCHEMA!r} (got {doc.get('schema')!r})")
    need(doc.get("kind") == "serving", "kind must be 'serving'")
    need(isinstance(doc.get("machine"), dict), "machine")
    for k in ("platform", "python", "jax", "devices"):
        need(k in doc["machine"], f"machine.{k}")
    need(isinstance(doc.get("env"), dict), "env")
    metrics = doc.get("metrics")
    need(isinstance(metrics, dict) and metrics, "metrics must be non-empty")
    for k, v in metrics.items():
        need(isinstance(k, str) and k, "metrics keys must be strings")
        need(isinstance(v, (int, float)), f"metrics.{k} must be numeric")
    for k in ("engine.tokens_per_sec", "engine.ttft_p50_ms",
              "engine.ttft_p95_ms", "engine.slot_utilization",
              "fleet.ttft_p50_ms", "fleet.queue_p95_ms",
              # the open-loop capacity sweep (benchmarks.bench_load) is a
              # required stage, not an optional extra
              "load.peak_sessions_per_sec", "load.knee_offered_per_sec",
              # the long-session overflow A/B (sink+recent eviction vs
              # legacy rollover) is required too
              "eviction.evictions", "eviction.evicted_tokens",
              "eviction.rollovers", "eviction.ttft_p50_ms",
              "eviction.rollover_rollovers"):
        need(k in metrics, f"metrics.{k}")


def load_snapshot(path: str = SNAPSHOT_PATH) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_snapshot(doc)
    return doc


def save_snapshot(doc: Dict, path: str = SNAPSHOT_PATH) -> None:
    validate_snapshot(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_kernels_snapshot(path: str = KERNELS_SNAPSHOT_PATH) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_kernels_snapshot(doc)
    return doc


def save_kernels_snapshot(doc: Dict,
                          path: str = KERNELS_SNAPSHOT_PATH) -> None:
    validate_kernels_snapshot(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_serving_snapshot(path: str = SERVING_SNAPSHOT_PATH) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_serving_snapshot(doc)
    return doc


def save_serving_snapshot(doc: Dict,
                          path: str = SERVING_SNAPSHOT_PATH) -> None:
    validate_serving_snapshot(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def _cell_key(c: Dict):
    """Gate key: (n, mode).  Pre-mode snapshots carried one implicit
    cell per N; those read as mode='baseline' so old and new documents
    stay comparable."""
    return int(c["n"]), str(c.get("mode", "baseline"))


def check_regression(committed: Dict, fresh: Dict,
                     tol: float = REGRESSION_TOL) -> List[str]:
    """Compare the fresh sweep's rollout-vs-eager ratios against the
    committed snapshot cell by cell, keyed on (n, mode).  Returns a list
    of human-readable failures (empty == gate passes).
    Machine-dependent absolutes are reported but never gated on."""
    failures = []
    old = {_cell_key(c): c for c in committed["cells"]}
    for c in fresh["cells"]:
        key = _cell_key(c)
        if key not in old:
            continue
        was = float(old[key]["median_ratio"])
        now = float(c["median_ratio"])
        if now < was * (1.0 - tol):
            failures.append(
                f"N={key[0]} mode={key[1]}: rollout/eager ratio regressed "
                f"{was:.2f} -> {now:.2f} (>{tol:.0%} drop)")
    return failures


def check_kernels_coverage(committed: Dict, fresh_rows) -> List[str]:
    """Kernel-microbench gate: every committed row name must still be
    produced by a fresh `bench_kernels.run()`.  Interpret-mode CPU
    timings are machine noise, so (unlike the fleet sweep's in-process
    ratios) they are recorded but never compared — the gate catches
    kernels silently dropping out of the bench, not slow runners."""
    fresh_names = {r.name for r in fresh_rows}
    return [f"kernel row {r['name']!r} missing from fresh bench"
            for r in committed["rows"] if r["name"] not in fresh_names]


def check_serving_coverage(committed: Dict,
                           fresh_metrics: Dict) -> List[str]:
    """Serving gate: every committed metric key must still be produced
    by a fresh `bench_serving.run()`.  Wall-clock absolutes (tok/s,
    TTFT ms) move with the runner, so — like the kernels gate — they are
    recorded but never compared; the gate catches serving metrics
    silently dropping out of the bench.  The eviction stage is required
    on BOTH sides (not just inherited from the committed key set), so a
    bench edit that drops the overflow A/B cannot slip through against
    an old snapshot."""
    missing = [f"serving metric {k!r} missing from fresh bench"
               for k in committed["metrics"] if k not in fresh_metrics]
    if not any(k.startswith("eviction.") for k in fresh_metrics):
        missing.append(
            "fresh serving bench produced no eviction.* stage "
            "(bench_serving.bench_eviction)")
    return missing


def _main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="re-run the rollout sweep + kernel bench (quick) "
                         "and fail on regression vs the committed "
                         "BENCH_fleet.json / BENCH_kernels.json")
    ap.add_argument("--validate", action="store_true",
                    help="only validate the committed snapshots' schemas")
    args = ap.parse_args()
    committed = load_snapshot()
    print(f"[snapshot] {SNAPSHOT_PATH}: schema {committed['schema']} OK, "
          f"{len(committed['cells'])} cells")
    kernels = load_kernels_snapshot()
    print(f"[snapshot] {KERNELS_SNAPSHOT_PATH}: schema "
          f"{kernels['schema']} OK, {len(kernels['rows'])} rows")
    serving = load_serving_snapshot()
    print(f"[snapshot] {SERVING_SNAPSHOT_PATH}: schema "
          f"{serving['schema']} OK, {len(serving['metrics'])} metrics")
    if args.validate or not args.check:
        return
    from benchmarks.bench_fleet import run_rollout
    from benchmarks.bench_kernels import run as run_kernels
    from benchmarks.bench_serving import run as run_serving
    fresh = run_rollout(write=False)
    failures = check_regression(committed, fresh)
    failures += check_kernels_coverage(kernels, run_kernels(quick=True))
    failures += check_serving_coverage(serving, run_serving(quick=True))
    for f in failures:
        print(f"[snapshot] REGRESSION {f}")
    if failures:
        sys.exit(1)
    print(f"[snapshot] gate OK (tolerance {REGRESSION_TOL:.0%})")


if __name__ == "__main__":
    _main()

"""Fig. 3 — accuracy saturation: MLLM accuracy vs encoding bitrate on
DeViBench; the knee mirrors the paper's 968 Kbps saturation point.

The whole ladder is evaluated as ONE stacked grid through the
vectorized DeViBench engine (bit-identical to mapping the serial
`accuracy_at_bitrate` over the rungs), and the knee is read with
`repro.core.recap_abr.saturation_point` — the same array op the
ReCap-ABR tau/gamma fit consumes."""
from __future__ import annotations

from benchmarks.common import Row, shared_benchmark, timed
from repro.core.recap_abr import saturation_point
from repro.devibench.pipeline import accuracy_grid

LADDER = [200, 290, 400, 710, 968, 1700, 3000, 4000]


def run(quick: bool = True):
    bench = shared_benchmark(quick)
    ladder = LADDER if not quick else [200, 400, 968, 4000]
    accs_arr, us = timed(accuracy_grid, bench, [float(k) for k in ladder])
    accs = {k: float(a) for k, a in zip(ladder, accs_arr)}
    rows = [Row(f"fig3.accuracy@{k}kbps", us / len(ladder),
                f"acc={accs[k]:.3f}") for k in ladder]
    knee = saturation_point([float(k) for k in ladder], accs_arr)
    rows.append(Row("fig3.saturation_knee_kbps", 0.0, f"{knee:.0f}"))
    print(f"[fig3] accuracy curve {accs} -> saturates at ~{knee:.0f} kbps "
          "(paper: 968 kbps)")
    return rows

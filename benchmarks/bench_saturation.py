"""Fig. 3 — accuracy saturation: MLLM accuracy vs encoding bitrate on
DeViBench; the knee mirrors the paper's 968 Kbps saturation point."""
from __future__ import annotations

from benchmarks.common import Row, shared_benchmark, timed
from repro.devibench.pipeline import accuracy_at_bitrate

LADDER = [200, 290, 400, 710, 968, 1700, 3000, 4000]


def run(quick: bool = True):
    bench = shared_benchmark(quick)
    rows = []
    accs = {}
    for kbps in (LADDER if not quick else [200, 400, 968, 4000]):
        acc, us = timed(accuracy_at_bitrate, bench, float(kbps))
        accs[kbps] = acc
        rows.append(Row(f"fig3.accuracy@{kbps}kbps", us, f"acc={acc:.3f}"))
    ks = sorted(accs)
    knee = next((k for k in ks if accs[k] >= 0.95 * accs[ks[-1]]), ks[-1])
    rows.append(Row("fig3.saturation_knee_kbps", 0.0, f"{knee}"))
    print(f"[fig3] accuracy curve {accs} -> saturates at ~{knee} kbps "
          "(paper: 968 kbps)")
    return rows

"""Fig. 10 — robustness to confidence errors: calibrated confidence vs
actual accuracy across the bitrate ladder (binned reliability curve).

The (record x bitrate) margins come from one stacked DeViBench grid and
the calibration is the vectorized `PlattCalibrator.batch` — no
per-record loop anywhere."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_benchmark, shared_calibrator, timed
from repro.devibench.engine import bitrate_ladder, evaluate_records


def run(quick: bool = True):
    bench = shared_benchmark(quick)
    cal = shared_calibrator(quick)
    recs = (bench.test + bench.validation)[: 40 if quick else 200]

    def collect():
        res = evaluate_records(bench.scenes, recs,
                               bitrate_ladder([200.0, 700.0, 1700.0]))
        return cal.batch(res.margins).ravel(), \
            res.correct.ravel().astype(np.float64)

    (confs, correct), us = timed(collect)
    # reliability: accuracy within confidence bins
    bins = [(0.0, 0.33), (0.33, 0.66), (0.66, 1.01)]
    rows = []
    accs = []
    for lo, hi in bins:
        m = (confs >= lo) & (confs < hi)
        acc = float(correct[m].mean()) if m.any() else float("nan")
        accs.append(acc)
        rows.append(Row(f"fig10.accuracy@conf[{lo:.2f},{hi:.2f})", us,
                        f"acc={acc:.2f},n={int(m.sum())}"))
    # alignment: higher-confidence bins must be more accurate
    mono = all(a <= b + 0.05 for a, b in zip(accs, accs[1:])
               if not (np.isnan(a) or np.isnan(b)))
    corr = float(np.corrcoef(confs, correct)[0, 1])
    rows.append(Row("fig10.confidence_accuracy_corr", us,
                    f"pearson={corr:.2f},monotone={mono}"))
    print(f"[fig10] confidence-accuracy corr={corr:.2f}, bins={accs} "
          "(paper: scores generally align with accuracy)")
    return rows

"""Framework kernel microbenchmarks.

CPU-interpret timings are NOT perf claims (TPU is the target — see the
roofline analysis for those); this bench validates the kernels run and
prints the derived arithmetic-intensity figures used in §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.qp_codec.ops import (qp_codec_frame, tick_codec_frames,
                                        zeco_codec_frames)


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: B=1, S=256, Hq=8, Hk=2, d=64
    B, S, Hq, Hk, d = 1, 256, 8, 2, 64
    q = jax.random.normal(key, (B, S, Hq, d), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hk, d), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hk, d), jnp.bfloat16)
    us = _time(fa_ops.flash_attention, q, k, v, bq=64, bk=64, interpret=True)
    flops = 4 * B * S * S * Hq * d  # QK^T + PV
    hbm = (q.size + 2 * k.size) * 2 + q.size * 2
    rows.append(Row("kernel.flash_attention.interp", us,
                    f"AI={flops / hbm:.0f}flops/byte"))

    # flash decode: B=4, KV 4k
    Sk = 2048 if quick else 32768
    q1 = jax.random.normal(key, (4, 1, Hq, d), jnp.bfloat16)
    kc = jax.random.normal(key, (4, Sk, Hk, d), jnp.bfloat16)
    vc = jax.random.normal(key, (4, Sk, Hk, d), jnp.bfloat16)
    us = _time(fd_ops.flash_decode, q1, kc, vc, jnp.full((4,), Sk),
               bk=512, interpret=True)
    flops = 4 * 4 * Sk * Hq * d
    hbm = 2 * kc.size * 2
    rows.append(Row("kernel.flash_decode.interp", us,
                    f"AI={flops / hbm:.2f}flops/byte(memory-bound)"))

    # qp codec: 256x256 frame
    frame = jax.random.uniform(key, (256, 256))
    qp = jnp.full((32, 32), 30.0)
    us = _time(qp_codec_frame, frame, qp, bs=256, interpret=True)
    rows.append(Row("kernel.qp_codec.interp", us,
                    f"blocks={32 * 32},fused_dct_quant_rate"))

    # fused zeco codec: boxes -> importance -> QP -> bisected encode,
    # 4 frames per launch
    frames4 = jax.random.uniform(key, (4, 256, 256))
    boxes = jnp.asarray(np.tile([[60., 60., 140., 140.],
                                 [10., 180., 70., 240.]], (4, 1, 1)))
    us = _time(zeco_codec_frames, frames4, boxes, jnp.full((4,), 2),
               jnp.ones(4, bool), jnp.full((4,), 8e4), interpret=True)
    rows.append(Row("kernel.zeco_codec_fused.interp", us,
                    f"frames=4,blocks={4 * 32 * 32},"
                    "box_to_bits_one_vmem_pass"))

    # tick megakernel: the rollout scan's whole per-tick client phase
    # (surface -> strided-probe bisection -> quantize -> rate) emitting
    # codec products instead of a reconstruction; 96x96 exercises the
    # partial-patch one-hot upsample path
    for hw in (256, 96):
        fr = jax.random.uniform(key, (4, hw, hw))
        us = _time(tick_codec_frames, fr, boxes, jnp.full((4,), 2),
                   jnp.ones(4, bool), jnp.full((4,), 8e4),
                   frame_hw=(hw, hw), probe_stride=2, interpret=True)
        rows.append(Row(f"kernel.tick_megakernel.hw{hw}.interp", us,
                        f"frames=4,blocks={4 * (hw // 8) ** 2},"
                        "tick_products_one_vmem_pass"))

    for r in rows:
        print(f"[kernels] {r.csv()}")
    return rows


def snapshot_doc(rows):
    """Wrap bench rows in the committed-snapshot envelope
    (BENCH_kernels.json; see benchmarks.snapshot)."""
    from benchmarks.snapshot import BENCH_SCHEMA, env_knobs, machine_info
    return {
        "schema": BENCH_SCHEMA,
        "kind": "kernels",
        "machine": machine_info(),
        "env": env_knobs(),
        "rows": [{"name": r.name, "us_per_call": r.us,
                  "derived": r.derived} for r in rows],
        "notes": "interpret-mode CPU timings — validation figures, not "
                 "perf claims; the snapshot gate checks row coverage "
                 "only (benchmarks.snapshot.check_kernels_coverage)",
    }


def _main() -> None:
    import argparse

    from benchmarks.common import QUICK
    from benchmarks.snapshot import KERNELS_SNAPSHOT_PATH, \
        save_kernels_snapshot

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="write BENCH_kernels.json from this run")
    args = ap.parse_args()
    rows = run(QUICK)
    if args.write:
        save_kernels_snapshot(snapshot_doc(rows))
        print(f"[kernels] snapshot -> {KERNELS_SNAPSHOT_PATH}")


if __name__ == "__main__":
    _main()

"""Fig. 13 — end-to-end trace-driven comparison.

Four systems x two CC algorithms on mobility traces with embedded QA:
    WebRTC | WebRTC+ReCapABR | WebRTC+ZeCoStream | Artic
Reports accuracy + average frame latency per cell; headline deltas are
Artic vs WebRTC (paper: +15.12% accuracy, -135.31 ms with BBR).

The whole (cc x system x seed) grid runs as ONE fleet call: every cell's
sessions advance in lockstep ticks with a single batched codec dispatch
per tick (repro.core.fleet), instead of the old serial per-episode loop.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, shared_calibrator
from repro.core.fleet import Fleet, FleetSession
from repro.core.session import QASample, SessionConfig
from repro.net.traces import fluctuating_trace, mobility_trace
from repro.video.scenes import make_scene

SYSTEMS = {
    "webrtc": dict(use_recap=False, use_zeco=False),
    "webrtc+recap": dict(use_recap=True, use_zeco=False),
    "webrtc+zeco": dict(use_recap=False, use_zeco=True),
    "artic": dict(use_recap=True, use_zeco=True),
}


def _qa(scene, duration, fps=10.0):
    """One question shortly after each content epoch begins — the user asks
    about what just appeared (§4.1 'newly appeared content'), giving every
    system the same runway within the epoch."""
    period = scene.code_period_frames / fps
    out, i = [], 0
    t = period + 0.5
    while t < duration * 0.95:
        out.append(QASample(t_ask=float(t),
                            obj_idx=i % len(scene.objects),
                            answer_window=min(4.0, period - 0.6)))
        i += 1
        t += period
    return out


def _tuned_tau(cal) -> float:
    """§6.2: tau tuned on the validation split — the confidence at which
    the detector reads comfortably (margin 0.5)."""
    return float(np.clip(cal(0.5), 0.55, 0.92))


def _spec(cc: str, flags: dict, seed: int, duration: float, cal
          ) -> FleetSession:
    # code epochs every 4 s: questions target *current* content, so late
    # or corrupted frames genuinely cost accuracy (paper §4.1 seen/unseen)
    sc = make_scene(["retail", "street", "office"][seed % 3],
                    seed % 2 == 1, seed=seed, code_period_frames=40)
    # paper §7.1: walking/driving segments filtered for *significant*
    # fluctuation — frequent switches across the full industry ladder
    # (incl. 290/400 Kbps levels) plus mobility fades
    if seed % 2:
        tr = mobility_trace("driving", duration, seed=seed)
    else:
        tr = fluctuating_trace(duration, switches_per_min=6, seed=seed)
    cfg = SessionConfig(duration=duration, cc_kind=cc, seed=seed,
                        tau=_tuned_tau(cal), **flags)
    return FleetSession(scene=sc, qa_samples=_qa(sc, duration), trace=tr,
                        cfg=cfg, calibrator=cal)


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    duration = 40.0 if quick else 90.0
    seeds = [0, 1] if quick else [0, 1, 2, 3, 4, 5]
    ccs = ["gcc", "bbr"]

    cells = [(cc, name, flags) for cc in ccs
             for name, flags in SYSTEMS.items()]
    specs = [_spec(cc, flags, s, duration, cal)
             for cc, name, flags in cells for s in seeds]
    t0 = time.perf_counter()
    metrics = Fleet(specs).run()
    us_total = (time.perf_counter() - t0) * 1e6

    # the whole grid is one fleet call, so per-cell wall time is not
    # individually measurable; the aggregate row carries the real cost
    rows = [Row("fig13.fleet_run", us_total,
                f"cells={len(cells)},sessions={len(specs)}")]
    results = {}
    for ci, (cc, name, _) in enumerate(cells):
        ms = metrics[ci * len(seeds):(ci + 1) * len(seeds)]
        acc = float(np.mean([m.accuracy for m in ms]))
        lat = float(np.mean([m.avg_latency_ms for m in ms]))
        used = float(np.mean([m.bandwidth_used for m in ms]))
        results[(cc, name)] = (acc, lat, used)
        rows.append(Row(f"fig13.{cc}.{name}", 0.0,
                        f"acc={acc:.3f},latency={lat:.0f}ms,"
                        "time=see:fig13.fleet_run"))
    for cc in ccs:
        a_acc, a_lat, _ = results[(cc, "artic")]
        w_acc, w_lat, _ = results[(cc, "webrtc")]
        rows.append(Row(f"fig13.{cc}.artic_vs_webrtc", 0.0,
                        f"acc+{100 * (a_acc - w_acc):.2f}pp,"
                        f"latency{a_lat - w_lat:+.0f}ms"))
        print(f"[fig13/{cc}] artic acc {w_acc:.3f}->{a_acc:.3f} "
              f"({100 * (a_acc - w_acc):+.2f}pp), latency "
              f"{w_lat:.0f}->{a_lat:.0f}ms ({a_lat - w_lat:+.0f}ms) "
              "(paper: +15.12pp, -135.31ms)")
    run.results = results  # reused by bench_overhead
    return rows

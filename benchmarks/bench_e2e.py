"""Fig. 13 — end-to-end trace-driven comparison.

Four systems x two CC algorithms on mobility traces with embedded QA:
    WebRTC | WebRTC+ReCapABR | WebRTC+ZeCoStream | Artic
Reports accuracy + average frame latency per cell; headline deltas are
Artic vs WebRTC (paper: +15.12% accuracy, -135.31 ms with BBR).

The whole (cc x system x seed) grid is declared as `ScenarioSpec`s and
runs through ONE `run_scenarios` call: the compiler folds every cell
into a single cohort whose sessions advance in lockstep ticks with one
batched codec dispatch per tick (repro.core.fleet underneath).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, shared_calibrator
from repro.api import SYSTEMS, grid, run_scenarios


def _tuned_tau(cal) -> float:
    """§6.2: tau tuned on the validation split — the confidence at which
    the detector reads comfortably (margin 0.5)."""
    return float(np.clip(cal(0.5), 0.55, 0.92))


def _seeded(spec):
    """Fill the seed-derived content/network axes of one grid point.

    Code epochs every 4 s ("fig13" preset): questions target *current*
    content, so late or corrupted frames genuinely cost accuracy (paper
    §4.1 seen/unseen).  Traces follow §7.1: walking/driving segments
    filtered for *significant* fluctuation — frequent switches across
    the full industry ladder (incl. 290/400 Kbps levels) plus mobility
    fades."""
    s = spec.seed
    return spec.with_(
        scene=["retail", "street", "office"][s % 3], moving=s % 2 == 1,
        scene_seed=s, trace_seed=s,
        trace="mobility.driving" if s % 2 else "fluctuating",
        trace_kwargs={} if s % 2 else dict(switches_per_min=6))


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    duration = 40.0 if quick else 90.0
    seeds = [0, 1] if quick else [0, 1, 2, 3, 4, 5]
    ccs = ["gcc", "bbr"]

    specs = [_seeded(p) for p in grid(
        "fig13", cc_kind=ccs, system=list(SYSTEMS), seed=seeds,
        duration=duration, tau=_tuned_tau(cal))]
    t0 = time.perf_counter()
    result = run_scenarios(specs, calibrator=cal)
    us_total = (time.perf_counter() - t0) * 1e6

    # the whole grid is one run_scenarios call, so per-cell wall time is
    # not individually measurable; the aggregate row carries the real cost
    rows = [Row("fig13.fleet_run", us_total,
                f"cells={len(ccs) * len(SYSTEMS)},sessions={len(specs)}")]
    agg = result.aggregate(by=("cc_kind", "system"),
                           fields=("accuracy", "avg_latency_ms",
                                   "bandwidth_used"))
    results = {k: (v["accuracy"], v["avg_latency_ms"], v["bandwidth_used"])
               for k, v in agg.items()}
    for (cc, name), (acc, lat, used) in results.items():
        rows.append(Row(f"fig13.{cc}.{name}", 0.0,
                        f"acc={acc:.3f},latency={lat:.0f}ms,"
                        "time=see:fig13.fleet_run"))
    for cc in ccs:
        a_acc, a_lat, _ = results[(cc, "artic")]
        w_acc, w_lat, _ = results[(cc, "webrtc")]
        rows.append(Row(f"fig13.{cc}.artic_vs_webrtc", 0.0,
                        f"acc+{100 * (a_acc - w_acc):.2f}pp,"
                        f"latency{a_lat - w_lat:+.0f}ms"))
        print(f"[fig13/{cc}] artic acc {w_acc:.3f}->{a_acc:.3f} "
              f"({100 * (a_acc - w_acc):+.2f}pp), latency "
              f"{w_lat:.0f}->{a_lat:.0f}ms ({a_lat - w_lat:+.0f}ms) "
              "(paper: +15.12pp, -135.31ms)")
    run.results = results  # reused by bench_overhead
    return rows

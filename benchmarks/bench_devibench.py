"""Table 2 / §6 — DeViBench construction pipeline statistics: sample
counts, acceptance / cross-verification yields, category distribution,
temporal-dependency split."""
from __future__ import annotations

from benchmarks.common import Row, shared_benchmark, timed


def run(quick: bool = True):
    bench, us = timed(shared_benchmark, quick)
    s = bench.stats
    rows = [
        Row("table2.n_qa_samples", us, f"{s['n_verified']}"),
        Row("table2.total_duration_s", us, f"{s['total_duration_s']:.0f}"),
        Row("table2.categories", us, f"{len(s['categories'])}x2"),
        Row("sec6.accept_rate", us,
            f"{100 * s['accept_rate']:.2f}% (paper 25.25%)"),
        Row("sec6.verify_rate", us,
            f"{100 * s['verify_rate']:.2f}% (paper 89.37%)"),
        Row("sec6.net_yield", us,
            f"{100 * s['net_yield']:.2f}% (paper 22.57%)"),
        Row("sec6.split", us,
            f"val={s['n_validation']},test={s['n_test']}"),
        Row("fig8.by_kind", us, str(s["by_kind"]).replace(",", ";")),
        Row("fig8.temporal", us, str(s["by_temporal"]).replace(",", ";")),
    ]
    print(f"[table2] {s['n_verified']} samples, accept "
          f"{100 * s['accept_rate']:.1f}%, verify "
          f"{100 * s['verify_rate']:.1f}%, net "
          f"{100 * s['net_yield']:.1f}% (paper: 25.25/89.37/22.57%)")
    return rows

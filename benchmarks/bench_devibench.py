"""Table 2 / §6 — DeViBench construction pipeline statistics, the
degradation-axis coverage of the vectorized grid engine, and the
vectorized-vs-serial grid throughput.

Degradation axes (repro.devibench.engine.DegradationSpec):

    bitrate     uniform-QP rate control at a bitrate cap (Fig. 3 sweep)
    requant     mid-flight partial loss: re-quantize cached coefficients
                toward the delivered bits (fleet partial-drop path)
    drop        streaming stall: answer from a stall_frames-old frame
    downscale   block-mean downscale -> encode -> nearest upscale

The speed section times the legacy per-record loop (`_encode_at` +
`_answer` per grid cell, one device dispatch pair per cell) against
`evaluate_records` (unique frames DCT'd once, every cell encoded and
answered in batched dispatches) on (4 scenes x 4 records x 6
degradations) grids at three frame sizes.  The two paths are
bit-identical (tests/test_devibench_engine.py); only the dispatch
structure differs.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, shared_benchmark, timed
from repro.devibench import pipeline as dvb
from repro.devibench.engine import (bitrate_ladder, default_degradations,
                                    evaluate_records)

SPEED_LADDER = [200.0, 400.0, 968.0, 1700.0, 3000.0, 4000.0]


def _speed_grid(hw: int, n_frames: int = 20):
    """4 scenes x 4 DISTINCT records (unfiltered QA; throughput only).

    Records are distinct (object, frame-time) questions, so the serial
    baseline is not charged for re-encoding duplicated cells — frame
    reuse across records happens only where questions naturally collide
    on a frame, exactly as in a real benchmark split."""
    rng = np.random.default_rng(0)
    scenes, records = dvb._propose(rng, 1, 4, 0, (hw, hw), n_frames)
    by_scene = {}
    for r in records:
        key = (r.obj_idx, r.t_frame)
        seen = by_scene.setdefault(r.scene_id, {})
        if key not in seen:
            seen[key] = r
    grid_recs, picked = [], 0
    for sid in sorted(by_scene):
        if len(by_scene[sid]) >= 4 and picked < 4:
            grid_recs += list(by_scene[sid].values())[:4]
            picked += 1
    return scenes, grid_recs


def _serial_grid(scenes, recs, degradations):
    out = np.empty((len(recs), len(degradations)), bool)
    for j, d in enumerate(degradations):
        for i, r in enumerate(recs):
            sc = scenes[r.scene_id]
            rx = dvb._encode_at(sc.render(r.t_frame), d.kbps)
            ans, _ = dvb._answer(sc, r, rx)
            out[i, j] = ans == r.answer
    return out


def run(quick: bool = True):
    bench, us = timed(shared_benchmark, quick)
    s = bench.stats
    rows = [
        Row("table2.n_qa_samples", us, f"{s['n_verified']}"),
        Row("table2.total_duration_s", us, f"{s['total_duration_s']:.0f}"),
        Row("table2.categories", us, f"{len(s['categories'])}x2"),
        Row("sec6.accept_rate", us,
            f"{100 * s['accept_rate']:.2f}% (paper 25.25%)"),
        Row("sec6.verify_rate", us,
            f"{100 * s['verify_rate']:.2f}% (paper 89.37%)"),
        Row("sec6.net_yield", us,
            f"{100 * s['net_yield']:.2f}% (paper 22.57%)"),
        Row("sec6.split", us,
            f"val={s['n_validation']},test={s['n_test']}"),
        Row("fig8.by_kind", us, str(s["by_kind"]).replace(",", ";")),
        Row("fig8.temporal", us, str(s["by_temporal"]).replace(",", ";")),
    ]
    print(f"[table2] {s['n_verified']} samples, accept "
          f"{100 * s['accept_rate']:.1f}%, verify "
          f"{100 * s['verify_rate']:.1f}%, net "
          f"{100 * s['net_yield']:.1f}% (paper: 25.25/89.37/22.57%)")

    # -- degradation-axis coverage on the shared benchmark -------------
    degr = default_degradations()
    res, grid_us = timed(dvb.evaluate, bench, degr, "all")
    for d, acc, refuse in zip(degr, res.accuracy(), res.refuse_rate()):
        rows.append(Row(f"devibench.acc[{d.label}]",
                        grid_us / len(degr),
                        f"acc={acc:.3f},refuse={refuse:.2f}"))
    print("[devibench] degradation grid: "
          + ", ".join(f"{d.label}={a:.2f}"
                      for d, a in zip(degr, res.accuracy())))

    # -- vectorized vs serial throughput, 4x4x6 grid, 3 frame sizes ----
    reps = 3 if quick else 5
    degr_b = bitrate_ladder(SPEED_LADDER)
    for hw in (64, 128, 256):
        scenes, recs = _speed_grid(hw)
        if len(recs) < 16:
            continue
        # warm both paths (jit compile / caches) before timing
        vec = evaluate_records(scenes, recs, degr_b)
        ser = _serial_grid(scenes, recs, degr_b)
        assert np.array_equal(ser, vec.correct), "parity violated"
        # interleaved serial/vectorized pairs + median-of-ratios: the
        # shared box's load swings hit both paths of a pair alike
        t_sers, t_vecs = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            _serial_grid(scenes, recs, degr_b)
            t_sers.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            evaluate_records(scenes, recs, degr_b)
            t_vecs.append(time.perf_counter() - t0)
        t_ser, t_vec = np.median(t_sers), np.median(t_vecs)
        speedup = float(np.median(np.asarray(t_sers)
                                  / np.asarray(t_vecs)))
        cells = len(recs) * len(degr_b)
        rows.append(Row(f"devibench.grid_speed@{hw}px", t_vec * 1e6,
                        f"serial={t_ser * 1e3:.0f}ms,"
                        f"vec={t_vec * 1e3:.0f}ms,"
                        f"speedup={speedup:.1f}x,"
                        f"cells_per_s={cells / t_vec:.0f}"))
        print(f"[devibench] 4x4x6 grid @{hw}px: serial "
              f"{t_ser * 1e3:.0f}ms, vectorized {t_vec * 1e3:.0f}ms "
              f"({speedup:.1f}x, {cells / t_vec:.0f} cells/s)")
    return rows
